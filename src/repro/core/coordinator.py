"""SpotOnCoordinator — the paper's checkpoint coordinator (Fig. 1).

Runs beside the workload (in-process here; a sidecar in the paper), and owns:

* scheduling **periodic checkpoints** (transparent mode),
* polling the metadata service and, on a ``Preempt`` event, taking an
  opportunistic **termination checkpoint** (transparent mode only — the
  application-specific mode *cannot checkpoint on demand*, per the paper),
* on restart, finding the **most recent valid checkpoint** and restoring,
* (beyond paper, needed at 1000-node scale) a **straggler policy** that turns a
  persistently slow instance into a voluntary eviction: checkpoint + replace.

Time accounting: when a ``TimeModel`` is given (virtual-time benchmarks), the
coordinator charges modeled durations to the clock — extract cost for async
periodic saves (write IO overlaps training), extract+write for blocking
termination / stage checkpoints, read cost for restores. In wall-clock mode
durations are charged by physics.
"""

from __future__ import annotations

import enum
import logging
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from ..checkpoint.async_ckpt import AsyncCheckpointer
from ..checkpoint.sharded import Snapshot, extract_snapshot
from ..checkpoint.store import CheckpointStore
from .clock import Clock, VirtualClock
from .events import first_preempt, MetadataService
from .policy import CheckpointPolicy, Mode

log = logging.getLogger("spoton")


class Signal(enum.Enum):
    CONTINUE = "continue"
    PREEMPTING = "preempting"   # stop cleanly before NotBefore
    STRAGGLER = "straggler"     # ask the pool for a replacement


@dataclass(frozen=True)
class TimeModel:
    """Virtual-time cost of checkpoint operations, by bytes moved."""

    extract_bw: float = 10e9     # device->host snapshot bandwidth
    write_bw: float = 0.5e9      # shared-NFS write bandwidth
    read_bw: float = 1.0e9       # shared-NFS read bandwidth
    latency_s: float = 2.0       # per-op fixed cost (mount, metadata, commit)

    def extract_s(self, nbytes: int) -> float:
        return nbytes / self.extract_bw

    def write_s(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.write_bw

    def read_s(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.read_bw


class StragglerDetector:
    """Flags an instance whose step time stays above factor×rolling-median."""

    def __init__(self, factor: float = 2.0, window: int = 50,
                 min_samples: int = 20, patience: int = 5):
        self.factor = factor
        self.window: deque[float] = deque(maxlen=window)
        self.min_samples = min_samples
        self.patience = patience
        self._slow_streak = 0

    def observe(self, step_duration_s: float) -> bool:
        if len(self.window) >= self.min_samples:
            median = sorted(self.window)[len(self.window) // 2]
            if step_duration_s > self.factor * median:
                self._slow_streak += 1
            else:
                self._slow_streak = 0
        self.window.append(step_duration_s)
        return self._slow_streak >= self.patience

    def reset(self) -> None:
        self._slow_streak = 0
        self.window.clear()


@dataclass
class CoordinatorStats:
    periodic_ckpts: int = 0
    termination_ckpts: int = 0
    termination_failures: int = 0
    stage_ckpts: int = 0
    restores: int = 0
    ckpt_bytes_written: int = 0
    ckpt_time_s: float = 0.0
    restore_time_s: float = 0.0


class SpotOnCoordinator:
    def __init__(
        self,
        store: CheckpointStore,
        policy: CheckpointPolicy,
        clock: Clock,
        *,
        mesh_info: dict | None = None,
        time_model: TimeModel | None = None,
        straggler: StragglerDetector | None = None,
    ):
        self.store = store
        self.policy = policy
        self.clock = clock
        self.mesh_info = mesh_info or {}
        self.time_model = time_model
        self.straggler = straggler
        self.stats = CoordinatorStats()
        self._async = AsyncCheckpointer(store) if policy.async_writes else None
        self._metadata: MetadataService | None = None
        self._instance_name: str | None = None
        self._last_periodic_at = clock.now()
        self._preempt_handled: set[str] = set()
        self._last_poll_at = -float("inf")

    # -- lifecycle --------------------------------------------------------------

    def attach_instance(self, metadata: MetadataService, name: str) -> None:
        """Bind to the (new) instance's metadata endpoint after (re)start."""
        self._metadata = metadata
        self._instance_name = name
        self._last_periodic_at = self.clock.now()
        if self.straggler is not None:
            self.straggler.reset()

    def detach(self) -> None:
        self._metadata = None
        self._instance_name = None

    # -- time accounting ---------------------------------------------------------

    def _charge(self, seconds: float) -> None:
        if self.time_model is not None and isinstance(self.clock, VirtualClock):
            self.clock.advance(seconds)

    # -- checkpoint actions --------------------------------------------------------

    def _save_periodic(self, step: int, state) -> None:
        t0 = self.clock.now()
        if self._async is not None:
            snap = self._async.save_async(step, state, kind="transparent",
                                          mesh_info=self.mesh_info)
        else:
            snap = extract_snapshot(state, step=step, mesh_info=self.mesh_info)
            self.store.save_snapshot(snap, kind="transparent")
        # async: trainer pays only the device->host extract; write overlaps
        cost = (self.time_model.extract_s(snap.nbytes) if self._async is not None
                else self.time_model.extract_s(snap.nbytes) + self.time_model.write_s(snap.nbytes)) \
            if self.time_model else 0.0
        self._charge(cost)
        self.stats.periodic_ckpts += 1
        self.stats.ckpt_bytes_written += snap.nbytes
        self.stats.ckpt_time_s += (self.clock.now() - t0)
        self._last_periodic_at = self.clock.now()

    def _save_termination(self, step: int, state, deadline: float) -> bool:
        """Opportunistic: returns False if the notice window was missed."""
        t0 = self.clock.now()
        budget = deadline - t0
        if budget <= 0:
            self.stats.termination_failures += 1
            return False
        try:
            if self._async is not None:
                info = self._async.save_urgent(step, state, mesh_info=self.mesh_info,
                                               timeout_s=max(budget, 0.1))
                nbytes = info.nbytes
            else:
                snap = extract_snapshot(state, step=step, mesh_info=self.mesh_info)
                info = self.store.save_snapshot(snap, kind="termination")
                nbytes = snap.nbytes
        except (TimeoutError, RuntimeError) as e:
            log.warning("termination checkpoint failed: %s", e)
            self.stats.termination_failures += 1
            return False
        cost = (self.time_model.extract_s(nbytes) + self.time_model.write_s(nbytes)) \
            if self.time_model else 0.0
        if self.time_model and cost > budget:
            # virtual-time world: the write would not have finished in time
            self._charge(budget)
            self.stats.termination_failures += 1
            return False
        self._charge(cost)
        self.stats.termination_ckpts += 1
        self.stats.ckpt_bytes_written += nbytes
        self.stats.ckpt_time_s += (self.clock.now() - t0)
        return True

    def on_stage_end(self, stage: int, step: int, state) -> None:
        """Application-specific checkpoint point (k-mer stage boundary)."""
        if not self.policy.stage_boundary_enabled:
            return
        t0 = self.clock.now()
        snap = extract_snapshot(state, step=step, mesh_info=self.mesh_info)
        self.store.save_snapshot(snap, kind="application",
                                 extra={"stage": stage})
        # app-specific saves are synchronous in the app's critical path
        self._charge(self.time_model.extract_s(snap.nbytes)
                     + self.time_model.write_s(snap.nbytes)
                     if self.time_model else 0.0)
        self.stats.stage_ckpts += 1
        self.stats.ckpt_bytes_written += snap.nbytes
        self.stats.ckpt_time_s += (self.clock.now() - t0)

    # -- the per-step hook ----------------------------------------------------------

    def on_step_end(self, step: int, state_provider: Callable[[], Any],
                    step_duration_s: float | None = None) -> Signal:
        now = self.clock.now()
        # 1. metadata poll (rate-limited like the paper's curl loop)
        preempt = None
        if self._metadata is not None and now - self._last_poll_at >= self.policy.poll_interval_s:
            self._last_poll_at = now
            doc = self._metadata.get_scheduled_events()
            preempt = first_preempt(doc, self._instance_name)
            if preempt is not None and preempt["EventId"] in self._preempt_handled:
                preempt = None
        # 2. eviction imminent
        if preempt is not None:
            self._preempt_handled.add(preempt["EventId"])
            log.info("Preempt notice for %s (NotBefore=%s)",
                     self._instance_name, preempt["NotBefore"])
            if self.policy.supports_on_demand:
                self._save_termination(step, state_provider(),
                                       deadline=float(preempt["NotBefore"]))
            # app-specific mode cannot act (paper semantics) — work since the
            # last stage boundary will be lost.
            self._metadata.acknowledge_event(preempt["EventId"])
            return Signal.PREEMPTING
        # 3. periodic checkpoint
        if (self.policy.periodic_enabled
                and now - self._last_periodic_at >= self.policy.periodic_interval_s):
            self._save_periodic(step, state_provider())
        # 4. straggler policy
        if (self.straggler is not None and step_duration_s is not None
                and self.straggler.observe(step_duration_s)):
            log.warning("instance %s flagged as straggler", self._instance_name)
            if self.policy.supports_on_demand:
                self._save_termination(step, state_provider(),
                                       deadline=self.clock.now() + 3600.0)
            return Signal.STRAGGLER
        return Signal.CONTINUE

    # -- restart ----------------------------------------------------------------------

    def restore_latest(self, template):
        """Most-recent-valid restore; returns (state, manifest) or None."""
        t0 = self.clock.now()
        try:
            state, man = self.store.restore(template)
        except FileNotFoundError:
            return None
        nbytes = sum(t["nbytes"] for t in man.tensors)
        self._charge(self.time_model.read_s(nbytes) if self.time_model else 0.0)
        self.stats.restores += 1
        self.stats.restore_time_s += (self.clock.now() - t0)
        return state, man

    def flush(self) -> None:
        if self._async is not None:
            self._async.wait_until_finished()

    def close(self) -> None:
        if self._async is not None:
            self._async.close()
            self._async = None
