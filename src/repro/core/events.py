"""Cloud metadata 'Scheduled Events' service — faithful to Azure's schema.

The paper's coordinator polls Azure's Scheduled Events endpoint
(``http://169.254.169.254/metadata/scheduledevents``) and reacts to events of
``EventType == "Preempt"`` which carry a ``NotBefore`` at least 30 s in the
future. We reproduce the JSON document shape exactly (DocumentIncarnation +
Events list) so a backend for the real endpoint is a drop-in replacement, and
we provide ``simulate_eviction()`` mirroring ``az vmss simulate-eviction`` —
the paper's own method of triggering evictions for evaluation.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Protocol

from .clock import Clock

PREEMPT = "Preempt"
DEFAULT_NOTICE_S = 30.0  # Azure guarantees a minimum of 30 seconds


@dataclass
class ScheduledEvent:
    event_id: str
    event_type: str              # Preempt | Terminate | Reboot | Freeze
    resources: list[str]
    not_before: float            # clock timestamp (seconds)
    event_status: str = "Scheduled"
    resource_type: str = "VirtualMachine"
    event_source: str = "Platform"
    description: str = ""

    def to_json(self) -> dict:
        return {
            "EventId": self.event_id,
            "EventType": self.event_type,
            "ResourceType": self.resource_type,
            "Resources": list(self.resources),
            "EventStatus": self.event_status,
            "NotBefore": self.not_before,
            "EventSource": self.event_source,
            "Description": self.description,
        }


class MetadataService(Protocol):
    """What the coordinator needs from the cloud. A production backend GETs the
    real non-routable endpoint; the simulator below implements it in-process."""

    def get_scheduled_events(self) -> dict: ...
    def acknowledge_event(self, event_id: str) -> None: ...


class SimulatedMetadataService:
    """Per-instance Scheduled Events document, driven by the simulator."""

    _ids = itertools.count(1)

    def __init__(self, clock: Clock, instance_name: str):
        self.clock = clock
        self.instance_name = instance_name
        self._incarnation = 1
        self._events: list[ScheduledEvent] = []

    # -- coordinator-facing (Azure API shape) --------------------------------

    def get_scheduled_events(self) -> dict:
        return {
            "DocumentIncarnation": self._incarnation,
            "Events": [e.to_json() for e in self._events],
        }

    def acknowledge_event(self, event_id: str) -> None:
        """Azure: POST with StartRequests expedites the event. We mark Started;
        the platform may then act before NotBefore."""
        for e in self._events:
            if e.event_id == event_id:
                e.event_status = "Started"

    # -- platform-facing ------------------------------------------------------

    def schedule_preempt(self, *, notice_s: float = DEFAULT_NOTICE_S) -> ScheduledEvent:
        ev = ScheduledEvent(
            event_id=f"EV-{next(self._ids):06d}",
            event_type=PREEMPT,
            resources=[self.instance_name],
            not_before=self.clock.now() + max(notice_s, DEFAULT_NOTICE_S),
            description="Spot VM is being preempted.",
        )
        self._events.append(ev)
        self._incarnation += 1
        return ev

    def simulate_eviction(self) -> ScheduledEvent:
        """Mirrors ``az vmss simulate-eviction``: same event type and minimum
        notice as a genuine Azure preemption (paper §III-B)."""
        return self.schedule_preempt(notice_s=DEFAULT_NOTICE_S)

    def clear(self) -> None:
        self._events.clear()
        self._incarnation += 1


def first_preempt(document: dict, instance_name: str | None = None) -> dict | None:
    """Extract the first Preempt event addressed to `instance_name` (or any)."""
    for ev in document.get("Events", ()):
        if ev.get("EventType") != PREEMPT:
            continue
        if instance_name is not None and instance_name not in ev.get("Resources", ()):
            continue
        return ev
    return None
