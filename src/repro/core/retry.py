"""Bounded retry with exponential backoff and jitter for primitive IO.

One policy object, one entry point. ``call_with_retry`` retries *transient*
failures (classified by errno — a flaky disk or NFS hiccup) a bounded
number of times with multiplicative backoff and seeded jitter, then
re-raises. Persistent conditions (ENOSPC, EDQUOT, EROFS) and anything
without an errno are never retried: retrying a full disk just burns the
eviction-notice window. ``SimulatedCrash`` is a ``BaseException`` and passes
straight through — a dead process does not retry.

The sleep function is injectable so ``VirtualClock.sleep`` drives
fake-clock tests, and the jitter RNG is injectable for determinism.

Process-wide ``io_retries`` / ``io_giveups`` counters are folded into
``CoordinatorStats`` by the coordinator (same pattern as codec yields).

Keep this module dependency-free (stdlib only): ``repro.checkpoint``
imports it lazily and must not drag in the rest of ``repro.core``.
"""

from __future__ import annotations

import errno
import logging
import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, TypeVar

log = logging.getLogger(__name__)

T = TypeVar("T")

__all__ = [
    "IO_RETRY",
    "POLL_RETRY",
    "RetryPolicy",
    "TRANSIENT_ERRNOS",
    "PERSISTENT_ERRNOS",
    "call_with_retry",
    "is_transient",
    "snapshot_stats",
]

#: Errnos worth a second attempt: the operation may succeed verbatim.
TRANSIENT_ERRNOS = frozenset({
    errno.EIO,
    errno.EAGAIN,
    errno.EBUSY,
    errno.EINTR,
    errno.ETIMEDOUT,
    getattr(errno, "ESTALE", errno.EIO),
    getattr(errno, "ECONNRESET", errno.EIO),
})

#: Errnos that describe a *state*, not an event — retrying cannot help.
PERSISTENT_ERRNOS = frozenset({
    errno.ENOSPC,
    errno.EDQUOT,
    errno.EROFS,
})


def is_transient(exc: BaseException) -> bool:
    """True when the failure is worth retrying verbatim."""
    if isinstance(exc, OSError) and exc.errno is not None:
        return exc.errno in TRANSIENT_ERRNOS
    return False


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff: attempt k sleeps
    ``min(base * multiplier**(k-1), max) * (1 ± jitter)``."""

    max_attempts: int = 3
    base_delay_s: float = 0.02
    max_delay_s: float = 2.0
    multiplier: float = 2.0
    jitter: float = 0.5

    def delay_s(self, attempt: int, rng: Optional[random.Random] = None) -> float:
        d = min(self.base_delay_s * self.multiplier ** (attempt - 1),
                self.max_delay_s)
        if self.jitter and rng is not None:
            d *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return max(d, 0.0)


#: Chunk/manifest writes and reads on the commit path: fail fast enough
#: that an urgent save still fits the eviction-notice window.
IO_RETRY = RetryPolicy(max_attempts=3, base_delay_s=0.02, max_delay_s=0.5)

#: Metadata-endpoint polls: more patient, the poll cadence is seconds.
POLL_RETRY = RetryPolicy(max_attempts=4, base_delay_s=0.2, max_delay_s=5.0)

_stats_lock = threading.Lock()
_io_retries = 0
_io_giveups = 0
_default_rng = random.Random(0x5907)


def snapshot_stats() -> Dict[str, int]:
    """Monotonic process-wide retry counters since import."""
    with _stats_lock:
        return {"io_retries": _io_retries, "io_giveups": _io_giveups}


def _count(retries: int = 0, giveups: int = 0) -> None:
    global _io_retries, _io_giveups
    with _stats_lock:
        _io_retries += retries
        _io_giveups += giveups


def call_with_retry(fn: Callable[[], T], *,
                    policy: RetryPolicy = IO_RETRY,
                    classify: Callable[[BaseException], bool] = is_transient,
                    sleep: Callable[[float], Any] = time.sleep,
                    rng: Optional[random.Random] = None,
                    describe: str = "io op") -> T:
    """Run ``fn`` with bounded retry on transient failures.

    Non-transient exceptions (per ``classify``) re-raise immediately;
    transient ones re-raise after ``policy.max_attempts`` total attempts.
    """
    attempt = 1
    while True:
        try:
            return fn()
        except Exception as exc:
            if not classify(exc):
                raise
            if attempt >= policy.max_attempts:
                _count(giveups=1)
                log.warning("%s: giving up after %d attempts (%s)",
                            describe, attempt, exc)
                raise
            delay = policy.delay_s(attempt, rng if rng is not None
                                   else _default_rng)
            _count(retries=1)
            log.debug("%s: transient failure (%s), retry %d/%d in %.3fs",
                      describe, exc, attempt, policy.max_attempts - 1, delay)
            sleep(delay)
            attempt += 1
