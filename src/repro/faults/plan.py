"""Deterministic fault plans for the checkpoint IO path.

A :class:`FaultPlan` is a seedable, thread-safe schedule of faults keyed by
*op name* — a short string each instrumented IO site passes to
``faults.inject.fault_point`` / ``faults.inject.write_bytes``. Rules fire on
the Nth matching call and can raise errno faults (EIO, ENOSPC, ...), tear a
write partway through, roll back a rename (modelling a crash before the
directory entry became durable), or abort the process-equivalent with
:class:`SimulatedCrash`.

Instrumented op names (the fault surface):

======================  ======================================================
op                      site
======================  ======================================================
``chunk.write``         chunk-pool tmp-file payload write (torn-capable)
``chunk.fsync``         fsync of the chunk tmp file
``chunk.replace``       before the chunk tmp -> final ``os.replace``
``chunk.replaced``      after that rename (rollback-capable)
``chunk.read``         chunk payload read/decode on the restore path
``manifest.write``      manifest tmp-file write (torn-capable)
``manifest.replace``    before the manifest tmp -> final ``os.replace``
``manifest.replaced``   after that rename (rollback-capable)
``marker.write``        COMMITTED marker write (torn-capable)
``shard.write``         v1 shard container payload write (torn-capable)
``dir.fsync``           ``ioutil.fsync_dir``
``file.mmap``           container mmap on the read path
``store.replace``       before the stage -> final directory rename
``store.replaced``      after that rename (rollback-capable)
``commit.<phase>``      ``store.save_snapshot`` phase boundaries: ``staged``,
                        ``shards_written``, ``manifest_written``,
                        ``uploads_flushed``, ``renamed``, ``committed``
``provider.poll``       cloud metadata poll in the coordinator
``peer.send``           peer chunk server GET send (``crash`` = the serving
                        member dies mid-transfer: half the payload, then EOF)
``peer.fetch``          peer chunk client fetch attempt (errno = unreachable)
``backend.head``        object-store HEAD (errno = endpoint unreachable)
``backend.get``         object-store ranged GET response (``torn`` = the
                        connection died mid-body: a prefix is returned and
                        the content-address check must reject it)
``backend.put``         object-store PUT / multipart part upload (``torn`` =
                        a truncated blob lands under the final key before
                        the sender dies — re-PUT must size-verify, never
                        trust existence)
``backend.complete``    after multipart complete (errno = lost ack, the
                        object IS committed; ``rollback`` = un-commit the
                        blob then crash, the rename-rollback analogue)
======================  ======================================================

Rules match ops by ``fnmatch`` pattern, so ``chunk.*`` targets the whole
chunk-pool commit and ``*`` everything.
"""

from __future__ import annotations

import errno
import fnmatch
import random
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "BACKEND_CRASH_POINTS",
    "COMMIT_CRASH_POINTS",
    "FaultPlan",
    "FaultRule",
    "Injection",
    "SimulatedCrash",
]


class SimulatedCrash(BaseException):
    """Process-kill equivalent injected at a crash point.

    Deliberately a ``BaseException``: no ``except Exception`` cleanup
    handler may tidy up after it, because a real SIGKILL would not run
    that handler either. Anything the crash leaves on disk is exactly the
    debris recovery must tolerate.
    """


_ERRNO_BY_NAME = {
    "eio": errno.EIO,
    "enospc": errno.ENOSPC,
    "edquot": errno.EDQUOT,
    "eagain": errno.EAGAIN,
    "ebusy": errno.EBUSY,
    "etimedout": errno.ETIMEDOUT,
    "erofs": errno.EROFS,
    "estale": getattr(errno, "ESTALE", errno.EIO),
}

#: Enumerated crash points covering ``save_snapshot``'s commit sequence in
#: delta (chunk-pool) mode. The matrix test in ``tests/test_faults.py``
#: aborts a save at each point, reopens the store, and asserts the recovery
#: invariant: ``latest_valid()`` is a bit-identical committed checkpoint
#: (the prior one for every point before the COMMITTED marker), and the
#: next save commits cleanly over the debris.
COMMIT_CRASH_POINTS: Tuple[Tuple[str, str], ...] = (
    ("commit.staged", "crash"),
    ("chunk.write", "torn"),
    ("chunk.write", "crash"),
    ("chunk.fsync", "eio"),
    ("chunk.replace", "crash"),
    ("chunk.replaced", "rollback"),
    ("commit.shards_written", "crash"),
    ("manifest.write", "torn"),
    ("manifest.replace", "crash"),
    ("manifest.replaced", "rollback"),
    ("commit.manifest_written", "crash"),
    ("commit.uploads_flushed", "crash"),
    ("store.replace", "crash"),
    ("store.replaced", "rollback"),
    ("commit.renamed", "crash"),
    ("marker.write", "torn"),
    ("marker.write", "crash"),
    ("commit.committed", "crash"),
)

#: Crash/fault points covering the object-store upload and commit path,
#: exercised by ``tests/test_backend.py`` with an object-store-backed pool.
#: Same invariant as :data:`COMMIT_CRASH_POINTS`: abort (or errno) a save at
#: each point and ``latest_valid()`` stays a bit-identical committed
#: checkpoint — persistent errnos don't fail the save at all, they spool it
#: locally and reconcile when the store returns.
BACKEND_CRASH_POINTS: Tuple[Tuple[str, str], ...] = (
    ("backend.head", "etimedout"),
    ("backend.get", "eio"),
    ("backend.get", "torn"),
    ("backend.put", "eio"),
    ("backend.put", "torn"),
    ("backend.put", "crash"),
    ("backend.complete", "eio"),
    ("backend.complete", "rollback"),
    ("backend.complete", "crash"),
)


@dataclass
class FaultRule:
    """One scheduled fault.

    ``op`` is an fnmatch pattern over op names. The rule arms on the
    ``nth`` (1-based) matching call and stays armed for ``count``
    consecutive matching calls (``count=-1`` = persistent, i.e. every call
    from the nth on — how a dead disk looks, and what exhausts a bounded
    retry). ``error`` selects the behaviour:

    - ``"crash"``    — raise :class:`SimulatedCrash` (process dies here)
    - ``"torn"``     — write a prefix of the payload, then crash
    - ``"rollback"`` — undo the just-completed rename, then crash (a rename
      that never became durable)
    - an errno name (``"eio"``, ``"enospc"``, ...) — raise ``OSError`` with
      that errno, as a flaky/full disk would
    """

    op: str
    nth: int = 1
    count: int = 1
    error: str = "crash"
    path_substr: str = ""
    torn_frac: float = 0.5
    _seen: int = field(default=0, repr=False)

    def matches(self, op: str, path: str) -> bool:
        if not fnmatch.fnmatchcase(op, self.op):
            return False
        return not self.path_substr or self.path_substr in path


@dataclass(frozen=True)
class Injection:
    """What the injector should do at a matched site."""

    action: str  # "crash" | "torn" | "rollback" | "errno"
    err: int = 0
    torn_frac: float = 0.5
    op: str = ""
    path: str = ""

    def to_oserror(self) -> OSError:
        import os

        return OSError(self.err, os.strerror(self.err), self.path or None)


class FaultPlan:
    """Seedable schedule of :class:`FaultRule`\\ s, safe to share across the
    writer/codec threads that execute a save."""

    def __init__(self, rules: Sequence[FaultRule] = (), seed: int = 0):
        self.rules: List[FaultRule] = [FaultRule(**r.__dict__) if isinstance(r, FaultRule) else r
                                       for r in rules]
        self.rng = random.Random(seed)
        self.injected: List[Tuple[str, str, str]] = []  # (action, op, path)
        self.op_counts: Dict[str, int] = {}
        self._lock = threading.Lock()

    def add(self, op: str, **kw: object) -> "FaultPlan":
        self.rules.append(FaultRule(op=op, **kw))  # type: ignore[arg-type]
        return self

    def check(self, op: str, path: str = "") -> Optional[Injection]:
        """Record one call at ``op`` and return the injection to perform,
        if any rule fires."""
        with self._lock:
            self.op_counts[op] = self.op_counts.get(op, 0) + 1
            for rule in self.rules:
                if not rule.matches(op, path):
                    continue
                rule._seen += 1
                if rule._seen < rule.nth:
                    continue
                if rule.count >= 0 and rule._seen >= rule.nth + rule.count:
                    continue
                inj = self._build(rule, op, path)
                self.injected.append((inj.action, op, path))
                return inj
        return None

    def _build(self, rule: FaultRule, op: str, path: str) -> Injection:
        if rule.error in ("crash", "torn", "rollback"):
            return Injection(action=rule.error, torn_frac=rule.torn_frac,
                             op=op, path=path)
        err = _ERRNO_BY_NAME.get(rule.error)
        if err is None:
            raise ValueError(f"unknown fault error kind: {rule.error!r}")
        return Injection(action="errno", err=err, op=op, path=path)

    def fired(self) -> int:
        with self._lock:
            return len(self.injected)
