"""Process-wide fault injection points for the checkpoint IO path.

The hot-path contract: with no plan installed, ``fault_point`` is one
global load and a ``None`` check, and ``write_bytes`` degrades to a plain
``f.write``. Install a :class:`~repro.faults.plan.FaultPlan` (usually via
the :func:`active` context manager in tests, or ``SPOTON_FAULTS=1`` torture
suites) and every instrumented site consults it.

Injected-fault totals are process-wide monotonic counters, mirrored into
``CoordinatorStats.faults_injected`` by the coordinator the same way codec
yields are folded.
"""

from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from typing import IO, Any, Callable, Dict, Iterator, Optional, Tuple, Union

from .plan import FaultPlan, Injection, SimulatedCrash

__all__ = [
    "active",
    "fault_point",
    "install",
    "response_bytes",
    "send_bytes",
    "snapshot_stats",
    "uninstall",
    "write_bytes",
]

_plan: Optional[FaultPlan] = None
_stats_lock = threading.Lock()
_injected_total = 0


def install(plan: FaultPlan) -> None:
    global _plan
    _plan = plan


def uninstall() -> None:
    global _plan
    _plan = None


@contextmanager
def active(plan: FaultPlan) -> Iterator[FaultPlan]:
    """Install ``plan`` for the duration of the block."""
    install(plan)
    try:
        yield plan
    finally:
        uninstall()


def snapshot_stats() -> Dict[str, int]:
    """Monotonic process-wide count of faults injected since import."""
    with _stats_lock:
        return {"faults_injected": _injected_total}


def _count_injection() -> None:
    global _injected_total
    with _stats_lock:
        _injected_total += 1


def _raise_for(inj: Injection) -> None:
    _count_injection()
    if inj.action == "errno":
        raise inj.to_oserror()
    raise SimulatedCrash(f"injected crash at {inj.op} ({inj.path or '?'})")


def fault_point(op: str, path: str = "",
                rollback: Union[Tuple[str, str],
                                Callable[[], None], None] = None) -> None:
    """Consult the installed plan at one IO site.

    ``rollback=(dst, back)`` marks a point immediately *after* an
    ``os.replace`` whose durability is not yet guaranteed: a ``rollback``
    rule undoes the rename (``dst`` -> ``back``) before crashing, modelling
    power loss before the directory entry hit the platter. A zero-arg
    callable serves the same role for non-rename commits — e.g. the
    object-store analogue un-commits the just-completed blob — and runs
    before the crash is raised (OSError from the undo is swallowed, like a
    lost disk would swallow it).
    """
    plan = _plan
    if plan is None:
        return
    inj = plan.check(op, path)
    if inj is None:
        return
    if inj.action == "rollback":
        _count_injection()
        if callable(rollback):
            try:
                rollback()
            except OSError:
                pass
        elif rollback is not None:
            dst, back = rollback
            try:
                # deliberately UN-does a commit-protocol rename (crash
                # simulation) — the durability rules don't apply to it
                os.replace(dst, back)  # spotlint: ignore[SPOT001, SPOT002]
            except OSError:
                pass
        raise SimulatedCrash(f"injected rename rollback at {op} ({inj.path or '?'})")
    _raise_for(inj)


def write_bytes(f: IO[Any], data: Any, *, op: str, path: str = "") -> None:
    """``f.write(data)`` with torn-write capability (bytes or str payloads).

    A ``torn`` rule writes only a prefix (``torn_frac`` of the payload),
    flushes so the partial bytes are really in the file, then crashes —
    the on-disk state a power cut leaves behind mid-write.
    """
    plan = _plan
    if plan is None:
        f.write(data)
        return
    inj = plan.check(op, path)
    if inj is None:
        f.write(data)
        return
    if inj.action == "torn":
        _count_injection()
        cut = max(0, min(len(data), int(len(data) * inj.torn_frac)))
        f.write(data[:cut])
        f.flush()
        raise SimulatedCrash(f"injected torn write at {op} "
                             f"({cut}/{len(data)} bytes, {inj.path or '?'})")
    _raise_for(inj)


def send_bytes(send: Callable[[Any], None], data: Any, *,
               op: str, path: str = "") -> None:
    """``send(data)`` with torn-*request* capability for network writers.

    A ``torn`` rule delivers only a prefix to ``send`` — the bytes that
    made it onto the wire before the sender died — then crashes. Unlike
    :func:`write_bytes` there is no file to flush: whatever the receiver
    committed from the prefix is the debris (e.g. a truncated blob under a
    final object key) that idempotent, size-verified re-upload must repair.
    """
    plan = _plan
    if plan is None:
        send(data)
        return
    inj = plan.check(op, path)
    if inj is None:
        send(data)
        return
    if inj.action == "torn":
        _count_injection()
        cut = max(0, min(len(data), int(len(data) * inj.torn_frac)))
        send(data[:cut])
        raise SimulatedCrash(f"injected torn send at {op} "
                             f"({cut}/{len(data)} bytes, {inj.path or '?'})")
    _raise_for(inj)


def response_bytes(data: bytes, *, op: str, path: str = "") -> bytes:
    """Filter a network *response* payload through the plan.

    A ``torn`` rule returns only a prefix — a connection that died
    mid-body, which the caller's content-address verification must catch
    and turn into a retry (no crash is raised: the *reader* survives a torn
    response, unlike a torn writer). Errno/crash/rollback rules raise.
    """
    plan = _plan
    if plan is None:
        return data
    inj = plan.check(op, path)
    if inj is None:
        return data
    if inj.action == "torn":
        _count_injection()
        cut = max(0, min(len(data), int(len(data) * inj.torn_frac)))
        return data[:cut]
    _raise_for(inj)
    return data  # unreachable: _raise_for always raises
