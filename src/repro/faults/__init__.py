"""Deterministic fault injection for torture-testing the checkpoint stack.

The dynamic twin of the spotlint static rules: where SPOT001/002 prove the
commit protocol is *shaped* right, this package kills it mid-flight — torn
writes, errno storms, rename rollbacks, and process-equivalent crashes at
every enumerated commit point — and the tests assert the recovery
invariant actually holds. See README "Fault injection & torture testing".
"""

from .inject import (active, fault_point, install, response_bytes,
                     send_bytes, snapshot_stats, uninstall, write_bytes)
from .plan import (BACKEND_CRASH_POINTS, COMMIT_CRASH_POINTS, FaultPlan,
                   FaultRule, Injection, SimulatedCrash)

__all__ = [
    "BACKEND_CRASH_POINTS",
    "COMMIT_CRASH_POINTS",
    "FaultPlan",
    "FaultRule",
    "Injection",
    "SimulatedCrash",
    "active",
    "fault_point",
    "install",
    "response_bytes",
    "send_bytes",
    "snapshot_stats",
    "uninstall",
    "write_bytes",
]
