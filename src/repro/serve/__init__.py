from .serve_step import make_decode_step, make_prefill, sample_greedy

__all__ = ["make_decode_step", "make_prefill", "sample_greedy"]
