"""Serving entry points: batched prefill and single-token decode.

These are the functions the decode_32k / long_500k dry-run cells lower:
`serve_step` = one new token against a seq_len-deep cache. Sampling is greedy
(argmax) by default; serving state (caches + position) is an ordinary pytree,
so the Spot-on coordinator can checkpoint *serving* sessions too — long-runs
of batch inference on spot capacity are exactly the paper's use case.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


def sample_greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def make_prefill(cfg: ModelConfig, *, cache_len: int | None = None):
    def prefill_fn(params, inputs):
        last_logits, caches, pos = prefill(params, cfg, inputs, cache_len=cache_len)
        return sample_greedy(last_logits), caches, pos
    return prefill_fn


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, inputs, caches, pos):
        logits, new_caches = decode_step(params, cfg, inputs, caches, pos)
        return sample_greedy(logits), logits, new_caches
    return serve_step


def generate(params, cfg: ModelConfig, prompt, n_tokens: int, *,
             cache_len: int | None = None):
    """Greedy generation loop (examples / tests; not the dry-run path)."""
    S = prompt.shape[1]
    cache_len = cache_len or (S + n_tokens)
    pre = jax.jit(make_prefill(cfg, cache_len=cache_len))
    step = jax.jit(make_decode_step(cfg))
    tok, caches, pos = pre(params, prompt)
    out = [tok]
    for i in range(n_tokens - 1):
        tok, _, caches = step(params, out[-1][:, None], caches, S + i)
        out.append(tok)
    return jnp.stack(out, axis=1)
