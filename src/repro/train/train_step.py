"""Training step: loss, grads, AdamW update; microbatch gradient accumulation
and configurable activation rematerialization.

TrainState is a plain dict so pytree key paths are stable across processes —
checkpoint names depend on them. Everything a resume needs lives here
(including the data-pipeline cursor and RNG key): the *transparent checkpoint*
is exactly this pytree.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import forward, init_params
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_update, init_opt_state

TrainState = dict  # {"params", "opt", "step", "rng", "data"}


def cross_entropy(logits, labels, *, chunk_tokens: int = 32768):
    """Mean CE over tokens, fp32 (stable log-softmax).

    Computed in token chunks via lax.map so the fp32 upcast of the (T, V)
    logits never materializes at once — with 256k-vocab models the one-shot
    fp32 logits tensor alone is tens of GiB per device.
    """
    B, S, V = logits.shape
    T = B * S
    lf = logits.reshape(T, V)
    yf = labels.reshape(T)
    n_chunks = max(1, T // chunk_tokens)
    while T % n_chunks != 0:
        n_chunks -= 1
    if n_chunks <= 1:
        l32 = lf.astype(jnp.float32)
        lse = jax.nn.logsumexp(l32, axis=-1)
        gold = jnp.take_along_axis(l32, yf[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    def chunk_loss(args):
        lc, yc = args
        l32 = lc.astype(jnp.float32)
        lse = jax.nn.logsumexp(l32, axis=-1)
        gold = jnp.take_along_axis(l32, yc[:, None], axis=-1)[:, 0]
        return jnp.sum(lse - gold)

    per_chunk = jax.lax.map(chunk_loss,
                            (lf.reshape(n_chunks, T // n_chunks, V),
                             yf.reshape(n_chunks, T // n_chunks)))
    return jnp.sum(per_chunk) / T


def fused_unembed_xent(hidden, head, labels, *, seq_chunks: int = 8):
    """Chunked fused unembed + cross-entropy (big-vocab memory optimization).

    The (B, S, V) logits tensor never exists: per *sequence* chunk, logits are
    computed (MXU matmul, fp32 accumulation), reduced to a loss sum, and
    recomputed in backward (jax.checkpoint). Chunking over the sequence dim —
    not flat tokens — keeps the batch dim data-sharded through the reshape;
    flat-token chunks cross device shard boundaries and force XLA to
    replicate token work across the vocab-sharded axis (measured 8× FLOP
    inflation on the 16×16 mesh).
    """
    from ..distributed.sharding import shard_microbatched
    B, S, D = hidden.shape
    n = min(seq_chunks, S)
    while S % n != 0:
        n -= 1

    @jax.checkpoint
    def chunk_fn(args):
        from ..distributed.sharding import shard_act
        xc, yc = args                      # (B, S/n, D), (B, S/n)
        logits = jnp.einsum("bsd,dv->bsv", xc, head,
                            preferred_element_type=jnp.float32)
        logits = shard_act(logits, "logits")   # keep vocab model-sharded
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    if n <= 1:
        return chunk_fn((hidden, labels)) / (B * S)
    hs = hidden.reshape(B, n, S // n, D).transpose(1, 0, 2, 3)
    ys = labels.reshape(B, n, S // n).transpose(1, 0, 2)
    hs, ys = shard_microbatched((hs, ys))   # (n, B, ...) with B dp-sharded
    per_chunk = jax.lax.map(chunk_fn, (hs, ys))
    return jnp.sum(per_chunk) / (B * S)


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, seed: int = 0) -> TrainState:
    params = init_params(cfg, jax.random.key(seed))
    return {
        "params": params,
        "opt": init_opt_state(params,
                              factored=opt_cfg.factored_second_moment),
        "step": jnp.zeros((), jnp.int32),
        "rng": jax.random.key_data(jax.random.key(seed + 1)),
        "data": {"next_batch_index": jnp.zeros((), jnp.int32)},
    }


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    remat: str = "none", microbatches: int = 1,
                    aux_weight: float | None = None, fused_ce: bool = True):
    """Returns train_step(state, batch) -> (state, metrics), jit-able."""
    aux_w = aux_weight if aux_weight is not None else \
        (cfg.moe.aux_loss_weight if cfg.moe else 0.0)

    def loss_fn(params, inputs, labels):
        if fused_ce:
            from ..models.transformer import unembed_weights
            hidden, aux, _ = forward(params, cfg, inputs, remat=remat,
                                     return_hidden=True)
            ce = fused_unembed_xent(hidden, unembed_weights(params, cfg), labels)
        else:
            logits, aux, _ = forward(params, cfg, inputs, remat=remat)
            ce = cross_entropy(logits, labels)
        return ce + aux_w * aux, (ce, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, (ce, aux)), grads = grad_fn(params, batch["inputs"], batch["labels"])
        return loss, ce, aux, grads

    def accumulate(params, batch):
        from ..distributed.sharding import shard_microbatched
        B = batch["inputs"].shape[0]
        assert B % microbatches == 0, (B, microbatches)
        mb = jax.tree.map(
            lambda x: x.reshape(microbatches, B // microbatches, *x.shape[1:]),
            batch)
        mb = shard_microbatched(mb)

        def body(acc, mbatch):
            loss, ce, aux, grads = single(params, mbatch)
            acc = jax.tree.map(jnp.add, acc,
                               {"loss": loss, "ce": ce, "aux": aux, "grads":
                                jax.tree.map(lambda g: g.astype(jnp.float32), grads)})
            return acc, None

        zero = {"loss": jnp.zeros((), jnp.float32),
                "ce": jnp.zeros((), jnp.float32),
                "aux": jnp.zeros((), jnp.float32),
                "grads": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                      params)}
        acc, _ = jax.lax.scan(body, zero, mb)
        inv = 1.0 / microbatches
        return (acc["loss"] * inv, acc["ce"] * inv, acc["aux"] * inv,
                jax.tree.map(lambda g: g * inv, acc["grads"]))

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        params = state["params"]
        if microbatches > 1:
            loss, ce, aux, grads = accumulate(params, batch)
        else:
            loss, ce, aux, grads = single(params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, opt_cfg)
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
            "rng": state["rng"],
            "data": {"next_batch_index": state["data"]["next_batch_index"] + 1},
        }
        metrics = {"loss": loss, "ce": ce, "aux": aux, **opt_metrics}
        return new_state, metrics

    return train_step


def state_template(state: TrainState):
    """Zero-valued template with identical structure/shapes/dtypes (restore)."""
    return jax.tree.map(lambda x: np.zeros(x.shape, x.dtype)
                        if hasattr(x, "shape") else x, state)


def state_template_on_device(state: TrainState, device=None):
    """Restore template whose array leaves carry a device sharding.

    Handing this to a streaming restore makes tensors land *on device*
    (decode and host→device transfers pipelined, int8 payloads widened
    on-device) instead of ending at host numpy and paying the transfer at
    first jit dispatch. Allocation-free: leaves are ShapeDtypeStructs.
    """
    sharding = jax.sharding.SingleDeviceSharding(
        device if device is not None else jax.devices()[0])
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype, sharding=sharding)
        if hasattr(x, "shape") else x, state)
