"""SpotTrainer — the paper's Fig. 1 workflow as a training-cluster loop.

One run = the life of a long-running workload on a spot Scale Set:

    provision instance → restore most-recent-valid checkpoint (or cold-start)
    → step loop [periodic ckpts | stage ckpts | eviction notice → termination
    ckpt] → instance dies → replacement provisions → restore → ... → complete.

The *workload* is a staged training job — `n_stages` plays metaSPAdes'
k-mer-stage role: the application-specific policy may checkpoint only at stage
boundaries, the transparent policy at any step. Stage completion times are
reported exactly as Table I reports per-K times (on the surviving lineage:
a crossing rolled back by an eviction doesn't count).

Two time modes:
  * wall mode (clock=WallClock, step_time_s=None): every train step really
    executes (jit) and durations are physical — integration tests, small runs.
  * virtual mode (clock=VirtualClock, step_time_s=x): steps still execute (the
    state evolution and checkpoint bytes are real) but the clock advances by a
    modeled per-step cost, and checkpoint/restore costs come from the
    coordinator's TimeModel — replaying the paper's multi-hour schedules in
    seconds, deterministically.
"""

from __future__ import annotations

import logging
import math
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from ..checkpoint import sharded
from ..core.clock import Clock
from ..core.coordinator import Signal, SpotOnCoordinator
from ..core.spot_sim import InstancePool
from ..data import PipelineState, TokenPipeline
from ..models.config import ModelConfig
from ..optim import AdamWConfig
from .train_step import (init_train_state, make_train_step, state_template,
                         state_template_on_device)

log = logging.getLogger("spoton")


@dataclass
class TrainJob:
    cfg: ModelConfig
    opt: AdamWConfig
    total_steps: int
    n_stages: int = 5                      # metaSPAdes used 5 k-mer stages
    batch: int = 8
    seq_len: int = 64
    seed: int = 0
    remat: str = "none"
    microbatches: int = 1

    def stage_boundaries(self) -> list[int]:
        return [math.ceil(self.total_steps * (i + 1) / self.n_stages)
                for i in range(self.n_stages)]


@dataclass
class RunReport:
    completed: bool
    total_time_s: float
    stage_times_s: list[float]             # per-stage durations (Table I rows)
    steps_executed: int                    # including rolled-back work
    lost_steps: int
    restores: int
    cold_starts: int
    instances_used: int
    evictions_seen: int
    final_loss: float
    coordinator: dict
    extra: dict = field(default_factory=dict)


class SpotTrainer:
    def __init__(self, job: TrainJob, coordinator: SpotOnCoordinator,
                 pool: InstancePool, clock: Clock, *,
                 step_time_s: float | None = None,
                 max_sessions: int = 200):
        self.job = job
        self.coord = coordinator
        self.pool = pool
        self.clock = clock
        self.ledger = coordinator.ledger   # shared virtual-time accounting
        self.step_time_s = step_time_s
        self.max_sessions = max_sessions
        cfg = job.cfg
        self.pipeline = TokenPipeline(
            vocab_size=cfg.vocab_size, batch=job.batch, seq_len=job.seq_len,
            seed=job.seed,
            embed_dim=None if cfg.embed_inputs else cfg.d_model,
            embed_dtype=np.dtype("float32") if cfg.dtype == "float32"
            else np.dtype("float32"))
        self._step_fn = jax.jit(make_train_step(
            cfg, job.opt, remat=job.remat, microbatches=job.microbatches))
        self._compiled_step = None    # AOT-compiled step (resume warm start)

    # -----------------------------------------------------------------------

    def _fresh_state(self):
        return init_train_state(self.job.cfg, self.job.opt, seed=self.job.seed)

    # -- fast resume --------------------------------------------------------

    def _compile_step(self, template):
        """AOT-compile the train step from abstract shapes — no state needed,
        so it can run while the checkpoint restore is still on disk. With a
        persistent XLA compilation cache this is a disk hit on every
        instance after the first."""
        state_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype)
            if hasattr(x, "shape") else x, template)
        batch_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            self.pipeline.batch_at(0))
        return self._step_fn.lower(state_sds, batch_sds).compile()

    def resume(self, template):
        """Eviction→first-step-back warm start.

        The MTTR window decomposes into restore + H2D + recompile + data
        seek; this overlaps them: step compilation runs on a side thread
        (abstract shapes only) while the streaming restore decodes the
        latest checkpoint straight onto the device, and the data pipeline
        fast-forwards to the restored cursor in O(1). Returns
        (state, manifest, step, pipeline_state) or None when no checkpoint
        exists (cold start — the compile still warms the session).
        """
        # an executable surviving from the previous session (same process)
        # is already warm; only the replacement-instance case pays a
        # compile, and it overlaps the restore below
        compile_ex = cfut = None
        if self._compiled_step is None:
            compile_ex = ThreadPoolExecutor(1,
                                            thread_name_prefix="spoton-compile")
            cfut = compile_ex.submit(self._compile_step, template)
        try:
            restored = self.coord.restore_latest(
                state_template_on_device(template))
            if cfut is not None:
                try:
                    self._compiled_step = cfut.result()
                except Exception as e:  # AOT is an optimization, never fatal:
                    log.warning("step precompile failed; jit will compile at "
                                "first dispatch: %s", e)
                    self._compiled_step = None
        finally:
            if compile_ex is not None:
                compile_ex.shutdown(wait=False)
        if restored is None:
            return None
        state, man = restored
        step = int(np.asarray(state["step"]))
        pstate = self.pipeline.fast_forward(
            int(np.asarray(state["data"]["next_batch_index"])))
        return state, man, step, pstate

    def run(self) -> RunReport:
        job = self.job
        clock = self.clock
        t_start = clock.now()
        boundaries = job.stage_boundaries()
        stage_cross_time: dict[int, float] = {}   # stage idx -> crossing time
        steps_executed = 0
        lost_steps = 0
        cold_starts = 0
        sessions = 0
        last_session_max_step = 0
        final_loss = float("nan")
        template = state_template(self._fresh_state())
        self.pool.start()
        completed = False

        while not completed and sessions < self.max_sessions:
            sessions += 1
            inst = self.pool.wait_for_instance()
            self.coord.attach_instance(inst.metadata, inst.name)
            resumed = self.resume(template)
            if resumed is not None:
                state, _man, step, pstate = resumed
            else:
                state = self._fresh_state()
                step = 0
                cold_starts += 1
                pstate = self.pipeline.fast_forward(0)
            # work executed beyond this restore point is lost
            if last_session_max_step > step:
                lost_steps += last_session_max_step - step
            # crossings beyond the restore point are invalidated (rolled back)
            for si in [s for s, _ in list(stage_cross_time.items())
                       if boundaries[s] > step]:
                stage_cross_time.pop(si, None)

            preempted = False
            while step < job.total_steps:
                if self.pool.tick() is None:       # platform killed the VM
                    break
                # the host-side cursor mirrors state["data"]["next_batch_index"]
                # (both advance by 1 per step; resume() re-syncs from the
                # restored state) — reading it here instead of the device
                # cursor saves a device→host sync per step
                batch = self.pipeline.batch_at(pstate.next_batch_index)
                t0 = clock.now()
                step_fn = (self._compiled_step if self._compiled_step is not None
                           else self._step_fn)
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                pstate = PipelineState(pstate.next_batch_index + 1)
                self.ledger.charge_step(self.step_time_s)
                dur = clock.now() - t0
                step += 1
                steps_executed += 1
                final_loss = float(np.asarray(metrics["loss"]))
                # stage boundary bookkeeping + app-specific checkpoint hook
                for si, b in enumerate(boundaries):
                    if step == b:
                        stage_cross_time[si] = clock.now()
                        self.coord.on_stage_end(si, step, state)
                # staging handoff: the supplier is invoked lazily, only when
                # the coordinator decides to checkpoint. The coordinator owns
                # the prestage call (it knows the save kind): periodic saves
                # prestage through the device-delta tracker — fingerprint +
                # diff compute instead of full-state DMAs — while urgent
                # saves prestage the plain way, never paying digest kernels
                # inside the eviction-notice window. The tracker's gathered
                # blocks are fresh device buffers, so the next step may
                # freely donate `state`.
                sig = self.coord.on_step_end(step, lambda s=state: s,
                                             step_duration_s=dur)
                if sig is Signal.PREEMPTING:
                    preempted = True
                    break
                if sig is Signal.STRAGGLER:
                    inst.terminate()
                    break
            last_session_max_step = step
            if step >= job.total_steps:
                completed = True
                break
            if preempted:       # ride the notice out until the platform kills us
                while self.pool.tick() is not None:
                    clock.sleep(1.0)
            self.coord.detach()

        self.coord.flush()
        self.pool.shutdown()
        total = clock.now() - t_start
        # per-stage durations on the surviving lineage
        stage_times = []
        prev = t_start
        for si in range(job.n_stages):
            t = stage_cross_time.get(si)
            if t is None:
                stage_times.append(float("nan"))
            else:
                stage_times.append(t - prev)
                prev = t
        st = self.coord.stats
        return RunReport(
            completed=completed,
            total_time_s=total,
            stage_times_s=stage_times,
            steps_executed=steps_executed,
            lost_steps=lost_steps,
            restores=st.restores,
            cold_starts=cold_starts,
            instances_used=self.pool.instances_created,
            evictions_seen=self.pool.evictions_announced,
            final_loss=final_loss,
            coordinator={
                "periodic_ckpts": st.periodic_ckpts,
                "termination_ckpts": st.termination_ckpts,
                "termination_failures": st.termination_failures,
                "rebalance_ckpts": st.rebalance_ckpts,
                "stage_ckpts": st.stage_ckpts,
                "ckpt_bytes_written": st.ckpt_bytes_written,
                "ckpt_time_s": st.ckpt_time_s,
                "d2h_bytes": st.d2h_bytes,
                "d2h_bytes_skipped": st.d2h_bytes_skipped,
                "save_stall_s": st.save_stall_s,
                "restore_queue_wait_s": st.restore_queue_wait_s,
                "restore_decode_s": st.restore_decode_s,
                "save_yields": st.save_yields,
                "io_retries": st.io_retries,
                "faults_injected": st.faults_injected,
                "saves_degraded": st.saves_degraded,
                "backend_retries": st.backend_retries,
                "backend_outages": st.backend_outages,
                "spooled_bytes": st.spooled_bytes,
                "poll_failures": st.poll_failures,
                "mttr_mean_s": st.mttr_mean_s,
                "mttr_samples": list(st.mttr_samples),
            },
            extra={"provider": self.coord.provider.name},
        )
