from .train_step import (TrainState, cross_entropy, init_train_state,
                         make_train_step, state_template,
                         state_template_on_device)
from .trainer import RunReport, SpotTrainer, TrainJob

__all__ = ["RunReport", "SpotTrainer", "TrainJob", "TrainState",
           "cross_entropy", "init_train_state", "make_train_step",
           "state_template", "state_template_on_device"]
