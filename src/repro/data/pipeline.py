"""Deterministic, checkpointable synthetic data pipeline.

The pipeline is a pure function of (seed, batch_index): batch *i* is always
the same array regardless of process restarts — so its entire mutable state is
one integer. That integer rides inside the transparent checkpoint, which is
what makes resume *bit-exact*: a restored job consumes exactly the batches it
would have consumed, in order. (The application-specific mode deliberately
omits pipeline state — like metaSPAdes re-deriving intra-stage progress — so
its resume replays data from the stage boundary.)

Host-side numpy (as a real input pipeline would be), O(batch) per call.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class PipelineState:
    next_batch_index: int = 0

    def to_tree(self) -> dict:
        return {"next_batch_index": np.int64(self.next_batch_index)}

    @staticmethod
    def from_tree(tree: dict) -> "PipelineState":
        return PipelineState(next_batch_index=int(tree["next_batch_index"]))

    @staticmethod
    def template() -> dict:
        return {"next_batch_index": np.int64(0)}


class TokenPipeline:
    """Synthetic LM batches: token ids, next-token labels; or frontend
    embeddings for [audio]/[vlm] archs (embed_dim set)."""

    def __init__(self, *, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, embed_dim: int | None = None,
                 embed_dtype=np.float32):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.embed_dim = embed_dim
        self.embed_dtype = embed_dtype

    def batch_at(self, index: int) -> dict:
        rng = np.random.Generator(np.random.PCG64(
            np.random.SeedSequence([self.seed, index])))
        # token stream with mild structure (Zipf-ish) so losses are non-trivial
        z = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        tokens = (z % self.vocab_size).astype(np.int32)
        labels = tokens[:, 1:]
        if self.embed_dim is not None:
            emb = rng.standard_normal(
                (self.batch, self.seq_len, self.embed_dim)).astype(self.embed_dtype)
            return {"inputs": emb, "labels": labels}
        return {"inputs": tokens[:, :-1], "labels": labels}

    def next(self, state: PipelineState) -> tuple[dict, PipelineState]:
        b = self.batch_at(state.next_batch_index)
        return b, PipelineState(state.next_batch_index + 1)

    def fast_forward(self, batch_index: int) -> PipelineState:
        """Seek to the restored cursor in O(1) — no replay.

        Because every batch is a pure function of (seed, batch_index), a
        resume needs no catch-up iteration over consumed data: the cursor
        from the checkpoint *is* the full pipeline state. A resumed job
        yields exactly the batches an uninterrupted run would have, in
        order. This is the data-pipeline leg of the fast-resume path — in
        MTTR terms it costs nothing, where a stateful loader would replay
        (or re-shard) up to ``batch_index`` batches.
        """
        if batch_index < 0:
            raise ValueError(f"batch index must be >= 0, got {batch_index}")
        return PipelineState(next_batch_index=int(batch_index))
